"""Fast-grid protocol suite: the frozen-protocol regression against the
PR-1 engine behavior, the merged ``p2m-codesign-sweep/v3`` two-protocol
artifact, and the frozen-vs-unfrozen co-design comparison (one shared
pretrain, identical batch streams — accuracy differences are the protocol,
not the data)."""
import json

import numpy as np
import pytest

from repro.core import sweep as engine

# the v1 (PR-1) per-record contract the refactor must keep intact
V1_RECORD_KEYS = (
    "label", "circuit", "null_mismatch", "t_intg_ms", "accuracy",
    "train_time_s", "train_time_per_step_s", "train_time_norm",
    "bandwidth_ratio", "bandwidth_norm", "backend_energy_conventional_j",
    "backend_energy_p2m_j", "energy_improvement", "sensor_energy_p2m_j",
    "layer1_spikes", "input_events", "retention_err_v")


@pytest.fixture(scope="module")
def fast_results():
    data, model, sweep_cfg, grid = engine.paper_setup(fast=True)
    return engine.run_protocols(data, model, sweep_cfg, grid,
                                log=lambda *_: None), grid


class TestFrozenRegression:
    """Seeded ``run_grid(..., protocol="frozen")`` on ``fast_grid()`` must
    keep the PR-1 record contract and orderings — the unfrozen refactor
    may not silently change the paper protocol."""

    def test_record_keys_unchanged(self, fast_results):
        results, _ = fast_results
        for r in results["frozen"].records:
            for k in V1_RECORD_KEYS:
                assert k in r, k

    def test_one_record_per_cell(self, fast_results):
        results, grid = fast_results
        recs = results["frozen"].records
        assert len(recs) == 3 * len(grid.t_intg_grid_ms)
        assert len({(r["label"], r["t_intg_ms"]) for r in recs}) == len(recs)

    def test_normalization_per_config(self, fast_results):
        results, _ = fast_results
        res = results["frozen"]
        for lab in res.labels:
            rs = [r for r in res.records if r["label"] == lab]
            base = max(rs, key=lambda r: r["t_intg_ms"])
            assert abs(base["bandwidth_norm"] - 1.0) < 1e-6
            assert abs(base["train_time_norm"] - 1.0) < 1e-6

    def test_retention_ordering_at_short_t(self, fast_results):
        """Fig 4: nullified retains better than switch better than basic
        at the shortest T_INTG."""
        results, grid = fast_results
        t_min = min(grid.t_intg_grid_ms)
        at_t = {r["label"]: r["retention_err_v"]
                for r in results["frozen"].records
                if r["t_intg_ms"] == t_min}
        assert at_t["c@m=0.06"] < at_t["b"] < at_t["a"]

    def test_retention_grows_with_t(self, fast_results):
        results, grid = fast_results
        t_min, t_max = min(grid.t_intg_grid_ms), max(grid.t_intg_grid_ms)
        for lab in ("a", "b"):
            by_t = {r["t_intg_ms"]: r["retention_err_v"]
                    for r in results["frozen"].records
                    if r["label"] == lab}
            assert by_t[t_max] > by_t[t_min], lab

    def test_accuracy_in_range_and_protocol_tagged(self, fast_results):
        results, _ = fast_results
        assert results["frozen"].protocol == "frozen"
        for r in results["frozen"].records:
            assert 0.0 <= r["accuracy"] <= 1.0
            assert r["protocol"] == "frozen"

    def test_single_protocol_artifact_keeps_contract(self, fast_results):
        """Schema string advances to v3, but the single-protocol artifact
        keeps the PR-1/PR-2 structural contract (grid block, protocol tag,
        plain-JSON serializability) on top of the new axis metadata."""
        results, _ = fast_results
        art = results["frozen"].to_artifact()
        assert art["schema"] == engine.SCHEMA_V3
        assert art["protocol"] == "frozen"
        assert art["grid"]["axes"] == ["null_mismatch"]   # default axes
        json.dumps(art)


class TestMergedArtifact:
    def test_contains_both_protocols(self, fast_results):
        results, grid = fast_results
        art = engine.protocols_artifact(results, extra_meta={"wall_s": 0.0})
        assert art["schema"] == engine.SCHEMA_V3
        assert art["protocols"] == ["frozen", "unfrozen"]
        assert len(art["records"]) == 2 * 3 * len(grid.t_intg_grid_ms)
        assert {r["protocol"] for r in art["records"]} == {
            "frozen", "unfrozen"}
        # every (protocol, label, T) cell exactly once
        cells = {(r["protocol"], r["label"], r["t_intg_ms"])
                 for r in art["records"]}
        assert len(cells) == len(art["records"])
        json.dumps(art)   # must serialize as-is

    def test_keeps_grid_and_retention_meta(self, fast_results):
        results, _ = fast_results
        art = engine.protocols_artifact(results)
        assert art["grid"]["labels"] == list(results["frozen"].labels)
        assert set(art["retention"]["mean_abs_error_v"]) == set(
            results["frozen"].labels)


class TestProtocolComparison:
    def test_unfrozen_at_least_frozen_at_shortest_t(self, fast_results):
        """The co-design acceptance bar: letting each circuit config learn
        its own layer-1 weights may not LOSE accuracy at the shortest
        T_INTG (where the circuit constraint bites hardest) vs the frozen
        paper protocol, for any config — same pretrain, same batches.

        Accuracy at this scale is quantized in 1/(batch·eval_batches)
        steps, so the comparison is exact ties-or-wins, not float noise
        (verified stable across seeds 0-3). If a jax/XLA upgrade ever
        flips an eval argmax and fails this, retune the fast sweep budget
        (more finetune steps widens the unfrozen margin) rather than
        adding a tolerance — a tolerance below one accuracy quantum is
        vacuous here."""
        results, grid = fast_results
        t_min = min(grid.t_intg_grid_ms)
        fro = {r["label"]: r["accuracy"] for r in results["frozen"].records
               if r["t_intg_ms"] == t_min}
        unf = {r["label"]: r["accuracy"] for r in results["unfrozen"].records
               if r["t_intg_ms"] == t_min}
        for lab in fro:
            assert unf[lab] >= fro[lab], (
                f"unfrozen lost accuracy for {lab} at T={t_min}ms: "
                f"{unf[lab]:.4f} < {fro[lab]:.4f}")

    def test_weight_independent_circuits_keep_frozen_retention(
            self, fast_results):
        """Circuits (b)/(c) have kernel-independent leak, so training
        layer 1 cannot change their retention error; config (a)'s is
        re-linearized around the learned kernel and may move."""
        results, _ = fast_results
        fro = {(r["label"], r["t_intg_ms"]): r["retention_err_v"]
               for r in results["frozen"].records}
        for r in results["unfrozen"].records:
            if r["label"] in ("b", "c@m=0.06"):
                np.testing.assert_allclose(
                    r["retention_err_v"],
                    fro[(r["label"], r["t_intg_ms"])], rtol=1e-6)

    def test_train_time_recorded_for_both(self, fast_results):
        results, _ = fast_results
        for res in results.values():
            for r in res.records:
                assert r["train_time_per_step_s"] > 0.0

    def test_learned_kernel_retention_surface(self, fast_results):
        """Unfrozen records carry the per-variant retention SURFACE over the
        whole T grid, re-linearized around that variant's learned kernel;
        its entry at the record's own T must equal the scalar
        retention_err_v, and weight-independent circuits (b)/(c) must match
        the frozen (pretrained-kernel) surface exactly."""
        results, grid = fast_results
        t_grid = list(grid.t_intg_grid_ms)
        fro = {r["label"]: r["retention_surface_v"]
               for r in results["frozen"].records}
        for r in results["unfrozen"].records:
            surf = r["retention_surface_v"]
            assert len(surf) == len(t_grid)
            ti = t_grid.index(r["t_intg_ms"])
            np.testing.assert_allclose(surf[ti], r["retention_err_v"],
                                       rtol=1e-5, atol=1e-8)
            if r["label"] in ("b", "c@m=0.06"):
                np.testing.assert_allclose(surf, fro[r["label"]], rtol=1e-6)
