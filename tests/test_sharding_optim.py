"""Sharding rules, optimizers, nn layer micro-tests, roofline HLO parser."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.configs.base import SHAPES


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def _fake_mesh(shape, axes):
    """Abstract mesh (no devices needed) for spec-resolution tests."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


class TestShardingRules:
    def test_param_specs_cover_tree(self):
        from repro.models import lm
        from repro.sharding import rules
        cfg = get_config("internlm2-1.8b")
        shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        mesh = _fake_mesh((2, 2), ("data", "model"))
        specs = rules.param_pspecs(shapes, cfg, mesh)
        flat_shapes = jax.tree.leaves(
            shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        flat_specs = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)

    def test_divisibility_fallback_replicates(self):
        """A dim that does not divide falls back to None, never errors."""
        from repro.sharding.rules import _resolve
        mesh = _fake_mesh((2, 3), ("data", "model"))
        spec = _resolve(("model", None), mesh, False, (7, 4))
        assert spec == P(None, None)
        spec2 = _resolve(("model", None), mesh, False, (9, 4))
        assert spec2 == P("model", None)

    def test_attention_weights_tp_sharded(self):
        from repro.models import lm
        from repro.sharding import rules
        from repro.utils import tree_paths
        cfg = get_config("qwen3-32b")
        shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        mesh = _fake_mesh((2, 16), ("data", "model"))
        specs = rules.param_pspecs(shapes, cfg, mesh)
        flat = dict(tree_paths(specs))
        wq = flat["blocks/attn/wq"]
        assert "model" in jax.tree.leaves(tuple(wq))
        # norms replicate
        assert flat["final_norm"] == P()

    def test_moe_ep_vs_tp_mode(self):
        from repro.sharding.rules import _moe_mode
        assert _moe_mode(get_config("granite-moe-1b-a400m")) == "EP"  # 32 % 16
        assert _moe_mode(get_config("grok-1-314b")) == "TP"           # 8 < 16

    def test_batch_specs_all_shapes(self):
        from repro.sharding import rules
        cfg = get_config("internlm2-1.8b")
        mesh = _fake_mesh((4, 2), ("data", "model"))
        for shape in SHAPES.values():
            specs = rules.input_pspecs(cfg, shape, mesh)
            assert "tokens" in specs and "labels" in specs

    def test_zero1_shards_moments(self):
        from repro.models import lm
        from repro.sharding import rules
        from repro.utils import tree_paths
        cfg = get_config("internlm2-1.8b")   # tp mode, zero1 on
        shapes = jax.eval_shape(lambda k: lm.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        mesh = _fake_mesh((4, 4), ("data", "model"))
        pspecs = rules.param_pspecs(shapes, cfg, mesh)
        zspecs = rules.zero1_pspecs(pspecs, shapes, mesh, cfg)
        flat_p = dict(tree_paths(pspecs))
        flat_z = dict(tree_paths(zspecs))
        # at least the big matmul moments must pick up a "data" axis
        n_data = sum(1 for k, v in flat_z.items()
                     if "data" in jax.tree.leaves(tuple(v)))
        assert n_data > len(flat_z) // 2


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


class TestOptim:
    def test_adamw_matches_reference_impl(self):
        """One AdamW step against a hand-computed update."""
        from repro.optim import adamw
        from repro.optim.optimizers import apply_updates
        lr, b1, b2, eps, wd = 0.1, 0.9, 0.999, 1e-8, 0.01
        opt = adamw(lr, b1, b2, eps, wd)
        p = {"w": jnp.array([1.0, -2.0])}
        g = {"w": jnp.array([0.5, 0.3])}
        st = opt.init(p)
        up, st = opt.update(g, st, p)
        m = (1 - b1) * np.array([0.5, 0.3])
        v = (1 - b2) * np.array([0.5, 0.3]) ** 2
        mhat = m / (1 - b1)
        vhat = v / (1 - b2)
        want = -lr * (mhat / (np.sqrt(vhat) + eps) + wd * np.array([1.0, -2.0]))
        np.testing.assert_allclose(np.asarray(up["w"]), want, rtol=1e-5)
        new_p = apply_updates(p, up)
        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   np.array([1.0, -2.0]) + want, rtol=1e-5)

    def test_clip_by_global_norm(self):
        from repro.optim import clip_by_global_norm
        g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 5.0) < 1e-5
        total = math.sqrt(sum(float(jnp.sum(x ** 2))
                              for x in jax.tree.leaves(clipped)))
        assert abs(total - 1.0) < 1e-4

    def test_schedules(self):
        from repro.optim.optimizers import warmup_cosine
        s = warmup_cosine(1.0, warmup_steps=10, total_steps=110)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(s(jnp.asarray(110))) <= 0.2

    def test_adamw_converges_quadratic(self):
        from repro.optim import adamw
        from repro.optim.optimizers import apply_updates
        opt = adamw(0.1, weight_decay=0.0)
        p = {"w": jnp.array([5.0])}
        st = opt.init(p)
        for _ in range(200):
            g = jax.grad(lambda q: jnp.sum((q["w"] - 2.0) ** 2))(p)
            up, st = opt.update(g, st, p)
            p = apply_updates(p, up)
        assert abs(float(p["w"][0]) - 2.0) < 0.05


# ---------------------------------------------------------------------------
# nn layers micro
# ---------------------------------------------------------------------------


class TestLayers:
    def test_rope_rotation_property(self):
        """RoPE: relative dot products depend only on position delta."""
        from repro.nn.layers import apply_rope
        d = 32
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, d))
        y = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
        def dot_at(p_q, p_k):
            q = apply_rope(x, jnp.array([[p_q]]), 10000.0)
            k = apply_rope(y, jnp.array([[p_k]]), 10000.0)
            return float(jnp.sum(q * k))
        assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-3
        assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-3

    def test_attention_core_matches_naive(self):
        from repro.kernels.flash_attention.ref import attention_ref
        from repro.nn.layers import attention_core
        B, S, H, KV, hd = 2, 24, 4, 2, 16
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (B, S, H, hd))
        kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, KV, hd))
        v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, KV, hd))
        out = attention_core(q, kk, v, causal=True, chunk=8)
        # naive with GQA expansion
        kk_e = jnp.repeat(kk, H // KV, axis=2)
        v_e = jnp.repeat(v, H // KV, axis=2)
        o_ref = attention_ref(
            jnp.moveaxis(q, 2, 1).reshape(B * H, S, hd),
            jnp.moveaxis(kk_e, 2, 1).reshape(B * H, S, hd),
            jnp.moveaxis(v_e, 2, 1).reshape(B * H, S, hd), causal=True)
        o_ref = jnp.moveaxis(o_ref.reshape(B, H, S, hd), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                                   rtol=2e-3, atol=2e-3)

    def test_attention_nondivisible_kv_pads(self):
        """Skv=10, chunk=8 → internal pad path (the llama-vision 1601 bug)."""
        from repro.kernels.flash_attention.ref import attention_ref
        from repro.nn.layers import attention_core
        B, Sq, Skv, hd = 1, 4, 10, 8
        k = jax.random.PRNGKey(0)
        q = jax.random.normal(k, (B, Sq, 2, hd))
        kk = jax.random.normal(jax.random.fold_in(k, 1), (B, Skv, 2, hd))
        v = jax.random.normal(jax.random.fold_in(k, 2), (B, Skv, 2, hd))
        out = attention_core(q, kk, v, causal=False, chunk=8)
        o_ref = attention_ref(
            jnp.moveaxis(q, 2, 1).reshape(2, Sq, hd),
            jnp.moveaxis(kk, 2, 1).reshape(2, Skv, hd),
            jnp.moveaxis(v, 2, 1).reshape(2, Skv, hd), causal=False)
        o_ref = jnp.moveaxis(o_ref.reshape(B, 2, Sq, hd), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                                   rtol=2e-3, atol=2e-3)

    def test_chunked_ce_matches_full(self):
        from repro.nn.layers import (chunked_cross_entropy, embed_init,
                                     softmax_cross_entropy, unembed_apply)
        cfg = smoke_variant(get_config("internlm2-1.8b"))
        p = embed_init(jax.random.PRNGKey(0), cfg)
        h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                    cfg.vocab_size)
        full = softmax_cross_entropy(unembed_apply(p, h, cfg), labels).mean()
        chunked = chunked_cross_entropy(p, h, labels, cfg, seq_chunk=4)
        np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)

    def test_rmsnorm_unit_scale(self):
        from repro.nn.layers import rmsnorm
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
        y = rmsnorm(x, jnp.ones((64,)))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


# ---------------------------------------------------------------------------
# roofline HLO parser
# ---------------------------------------------------------------------------


class TestRooflineParser:
    def test_counts_scanned_loop_flops(self):
        """A scan over L matmuls must count L× the FLOPs (the whole point
        of the loop-aware parser vs cost_analysis)."""
        from repro.roofline.hlo import analyze_hlo
        L, n = 8, 32
        w = jax.random.normal(jax.random.PRNGKey(0), (L, n, n))

        def f(x, ws):
            def body(h, wi):
                return h @ wi, None
            h, _ = jax.lax.scan(body, x, ws)
            return h

        hlo = jax.jit(f).lower(jnp.ones((n, n)), w).compile().as_text()
        parsed = analyze_hlo(hlo)
        want = 2 * n * n * n * L
        assert parsed.flops >= want * 0.9, (parsed.flops, want)
        assert parsed.flops <= want * 1.5

    def test_collective_bytes_all_reduce(self):
        from repro.roofline.hlo import analyze_hlo
        hlo = """
HloModule m
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024] parameter(0)
  ROOT %ar = f32[1024] all-reduce(%p), replica_groups={{0,1}}, to_apply=%add
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
        parsed = analyze_hlo(hlo)
        assert parsed.collective_bytes == 1024 * 4

    def test_roofline_terms_dominance(self):
        from repro.roofline.model import roofline_terms
        t = roofline_terms(flops=1e18, bytes_accessed=1e12,
                           collective_bytes=1e10, chips=256)
        assert t["dominant"] == "compute"
        t2 = roofline_terms(flops=1e12, bytes_accessed=1e15,
                            collective_bytes=1e10, chips=256)
        assert t2["dominant"] == "memory"
