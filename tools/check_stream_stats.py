#!/usr/bin/env python3
"""Assert a serving-stats artifact matches the p2m-stream-serving
schema (docs/streaming.md), version-aware across v2/v3/v4/v5. Stdlib
only — the CI streaming-smoke steps run it against the artifacts
`launch/stream.py --smoke` just emitted (unpaced, ``--paced``,
lane-sharded, ``--registry`` multi-variant, and ``--adapt``).

Version history the gate understands:

* **v2** — paced serving: admission ledger (offered = admitted + shed),
  deadline accounting (margins, histogram), latency percentiles.
* **v3** — lane-mesh sharding: the ``sharding`` block (devices,
  bin_workers, padded_capacity, lanes_per_shard, per_shard_admitted,
  internally consistent and summing to n_admitted) and
  ``throughput.events_per_s_per_device``.
* **v4** — deployment registry: the ``registry`` block (compat digest,
  ``max_entries``, per-entry admitted/finished/miss/throughput rows),
  ``admission.n_rejected`` in the ledger (offered = admitted + shed +
  rejected), and per-stream ``entry``/``entry_uid`` binding. The
  per-entry ledger must sum to the fleet totals and every stream's
  entry must appear in the registry rows.
* **v5** — online adaptation: the ``adaptation`` block (rule + learning
  rates, per-lane update counts and delta norms, pre/post-accuracy
  split). A disabled block must carry zero updates and no lane rows; an
  enabled block's per-lane update counts must sum to the fleet total.

    python tools/check_stream_stats.py artifacts/stream/stream_serving_dvs128.json [--streams N]
    python tools/check_stream_stats.py --paced --max-miss-rate 1.0 paced.json
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA_PREFIX = "p2m-stream-serving/v"
VERSIONS = (2, 3, 4, 5)
SCHEMA = f"{SCHEMA_PREFIX}{VERSIONS[-1]}"   # current

TOP_KEYS = {"schema", "deployed", "n_streams", "capacity",
            "chunks_per_window", "t_intg_ms", "accuracy", "paced",
            "admission", "deadlines", "streams", "latency_ms",
            "throughput"}
STREAM_KEYS = {"stream_id", "label", "prediction", "correct", "n_events",
               "n_readouts", "n_coarse_frames", "offered_window",
               "admitted_window", "finished_window", "n_misses", "logits"}
ADMISSION_KEYS = {"offered_rate", "max_pending", "n_offered", "n_admitted",
                  "n_shed", "n_deferred", "max_open_streams"}
DEADLINE_KEYS = {"n_deadlines", "n_misses", "miss_rate", "margin_ms",
                 "histogram"}
MARGIN_KEYS = {"p50", "p90", "p99", "max"}
LATENCY_KEYS = {"readout_p50", "readout_p99", "readout_mean", "fold_p50",
                "fold_p99"}
THROUGHPUT_KEYS = {"wall_s", "events_per_s", "readouts_per_s",
                   "streams_per_s"}
SHARDING_KEYS = {"devices", "bin_workers", "padded_capacity",
                 "lanes_per_shard", "per_shard_admitted"}
REGISTRY_KEYS = {"compat", "max_entries", "entries"}
ENTRY_KEYS = {"name", "uid", "n_admitted", "n_finished", "n_correct",
              "n_misses", "n_events", "n_readouts", "accuracy",
              "events_per_s"}
ADAPT_KEYS = {"enabled", "rule", "lr_w", "lr_theta", "n_updates",
              "accuracy_pre", "accuracy_post", "lanes"}
ADAPT_LANE_KEYS = {"lane", "n_updates", "dw_norm", "dtheta"}
ADAPT_RULES = ("surrogate", "reward")


def schema_version(art: dict) -> int | None:
    """Parse the artifact's schema version; None when unrecognized."""
    s = art.get("schema")
    if not isinstance(s, str) or not s.startswith(SCHEMA_PREFIX):
        return None
    try:
        v = int(s[len(SCHEMA_PREFIX):])
    except ValueError:
        return None
    return v if v in VERSIONS else None


def check(art: dict, n_streams: int | None = None, paced: bool = False,
          max_miss_rate: float | None = None) -> list[str]:
    errs = []
    v = schema_version(art)
    if v is None:
        return [f"unrecognized schema {art.get('schema')!r} — expected "
                f"{SCHEMA_PREFIX}{{{','.join(map(str, VERSIONS))}}}"]
    top = set(TOP_KEYS)
    stream_keys = set(STREAM_KEYS)
    adm_keys = set(ADMISSION_KEYS)
    thr_keys = set(THROUGHPUT_KEYS)
    if v >= 3:
        top |= {"sharding"}
        thr_keys |= {"events_per_s_per_device"}
    if v >= 4:
        top |= {"registry"}
        adm_keys |= {"n_rejected"}
        stream_keys |= {"entry", "entry_uid"}
    if v >= 5:
        top |= {"adaptation"}
    missing = top - set(art)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
    streams = art.get("streams", [])
    if n_streams is not None and len(streams) != n_streams:
        errs.append(f"expected {n_streams} streams, got {len(streams)}")
    if art.get("n_streams") != len(streams):
        errs.append("n_streams does not match len(streams)")
    for i, s in enumerate(streams):
        miss = stream_keys - set(s)
        if miss:
            errs.append(f"stream[{i}] missing {sorted(miss)}")
            break
        if s["n_events"] <= 0 or s["n_readouts"] <= 0:
            errs.append(f"stream[{i}] has empty serving counters: {s}")
        if s["n_coarse_frames"] <= 0:
            errs.append(f"stream[{i}] produced no coarse backbone frames "
                        f"— its prediction is vacuous")
        if not 0 <= s["n_misses"] <= s["n_readouts"]:
            errs.append(f"stream[{i}] miss counter out of range: "
                        f"{s['n_misses']} of {s['n_readouts']} readouts")
    adm = art.get("admission", {})
    if adm_keys - set(adm):
        errs.append(f"admission missing {sorted(adm_keys - set(adm))}")
    else:
        n_rejected = adm.get("n_rejected", 0) if v >= 4 else 0
        if adm["n_offered"] != adm["n_admitted"] + adm["n_shed"] + n_rejected:
            errs.append(
                f"admission ledger does not balance: offered "
                f"{adm['n_offered']} != admitted {adm['n_admitted']} + "
                f"shed {adm['n_shed']}"
                + (f" + rejected {n_rejected}" if v >= 4 else ""))
        if adm["n_admitted"] != len(streams):
            errs.append(f"n_admitted {adm['n_admitted']} != "
                        f"{len(streams)} served streams (every admitted "
                        f"stream must finish)")
        cap = art.get("capacity", 0)
        if adm["max_open_streams"] > cap:
            errs.append(f"max_open_streams {adm['max_open_streams']} "
                        f"exceeds capacity {cap} — streams were opened "
                        f"before a lane was free (eager admission)")
    ddl = art.get("deadlines", {})
    if DEADLINE_KEYS - set(ddl):
        errs.append(f"deadlines missing {sorted(DEADLINE_KEYS - set(ddl))}")
    else:
        if MARGIN_KEYS - set(ddl.get("margin_ms", {})):
            errs.append(f"deadlines.margin_ms missing "
                        f"{sorted(MARGIN_KEYS - set(ddl['margin_ms']))}")
        if not 0.0 <= ddl["miss_rate"] <= 1.0:
            errs.append(f"miss_rate out of range: {ddl['miss_rate']}")
        if ddl["n_misses"] > ddl["n_deadlines"]:
            errs.append(f"n_misses {ddl['n_misses']} > n_deadlines "
                        f"{ddl['n_deadlines']}")
        if art.get("paced"):
            if ddl["n_deadlines"] <= 0:
                errs.append("paced run recorded no deadlines")
        elif ddl["n_deadlines"] != 0:
            errs.append(f"unpaced run carries {ddl['n_deadlines']} "
                        f"deadlines — only paced readouts have them")
        if (max_miss_rate is not None
                and ddl["miss_rate"] * 100.0 > max_miss_rate):
            errs.append(f"miss rate {ddl['miss_rate']:.2%} exceeds "
                        f"--max-miss-rate {max_miss_rate}%")
    if v >= 3:
        errs += _check_sharding(art, adm)
    if v >= 4:
        errs += _check_registry(art, adm, streams, ddl)
    if v >= 5:
        errs += _check_adaptation(art)
    if paced and not art.get("paced"):
        errs.append("--paced: artifact is not a paced run")
    if LATENCY_KEYS - set(art.get("latency_ms", {})):
        errs.append(f"latency_ms missing "
                    f"{sorted(LATENCY_KEYS - set(art.get('latency_ms', {})))}")
    thr = art.get("throughput", {})
    if thr_keys - set(thr):
        errs.append(f"throughput missing {sorted(thr_keys - set(thr))}")
    elif not thr["events_per_s"] > 0 or not thr["readouts_per_s"] > 0:
        errs.append(f"throughput not positive: {thr}")
    if not 0.0 <= art.get("accuracy", -1) <= 1.0:
        errs.append(f"accuracy out of range: {art.get('accuracy')}")
    return errs


def _check_sharding(art: dict, adm: dict) -> list[str]:
    errs = []
    sh = art.get("sharding", {})
    if SHARDING_KEYS - set(sh):
        errs.append(f"sharding missing {sorted(SHARDING_KEYS - set(sh))}")
        return errs
    if sh["devices"] < 1 or sh["bin_workers"] < 1:
        errs.append(f"sharding counts must be >= 1: {sh}")
    if sh["lanes_per_shard"] * sh["devices"] != sh["padded_capacity"]:
        errs.append(f"sharding geometry inconsistent: "
                    f"{sh['lanes_per_shard']} lanes/shard x "
                    f"{sh['devices']} devices != padded capacity "
                    f"{sh['padded_capacity']}")
    if sh["padded_capacity"] < art.get("capacity", 0):
        errs.append(f"padded_capacity {sh['padded_capacity']} < "
                    f"capacity {art.get('capacity')}")
    if len(sh["per_shard_admitted"]) != sh["devices"]:
        errs.append(f"per_shard_admitted has "
                    f"{len(sh['per_shard_admitted'])} entries for "
                    f"{sh['devices']} devices")
    elif ("n_admitted" in adm
            and sum(sh["per_shard_admitted"]) != adm["n_admitted"]):
        errs.append(f"per-shard admits {sh['per_shard_admitted']} sum "
                    f"to {sum(sh['per_shard_admitted'])} != "
                    f"n_admitted {adm['n_admitted']}")
    return errs


def _check_registry(art: dict, adm: dict, streams: list,
                    ddl: dict) -> list[str]:
    """v4: the per-entry ledger must sum to the fleet totals, and every
    served stream's entry binding must name a registry row."""
    errs = []
    reg = art.get("registry", {})
    if REGISTRY_KEYS - set(reg):
        errs.append(f"registry missing {sorted(REGISTRY_KEYS - set(reg))}")
        return errs
    if not isinstance(reg["compat"], str) or not reg["compat"]:
        errs.append(f"registry.compat must be a non-empty digest, got "
                    f"{reg['compat']!r}")
    if not isinstance(reg["max_entries"], int) or reg["max_entries"] < 1:
        errs.append(f"registry.max_entries must be >= 1, got "
                    f"{reg['max_entries']!r}")
    rows = reg["entries"]
    row_keys = set()
    for i, row in enumerate(rows):
        miss = ENTRY_KEYS - set(row)
        if miss:
            errs.append(f"registry.entries[{i}] missing {sorted(miss)}")
            return errs
        k = (row["name"], row["uid"])
        if k in row_keys:
            errs.append(f"registry.entries has duplicate row for {k}")
        row_keys.add(k)
        if not 0 <= row["n_finished"] <= row["n_admitted"]:
            errs.append(f"entry {k}: n_finished {row['n_finished']} out "
                        f"of range for n_admitted {row['n_admitted']}")
        if not 0 <= row["n_correct"] <= row["n_finished"]:
            errs.append(f"entry {k}: n_correct {row['n_correct']} out of "
                        f"range for n_finished {row['n_finished']}")
        if not 0.0 <= row["accuracy"] <= 1.0:
            errs.append(f"entry {k}: accuracy out of range: "
                        f"{row['accuracy']}")
    for total, fleet, label in (
            ("n_admitted", adm.get("n_admitted"), "admission.n_admitted"),
            ("n_finished", len(streams), "served stream count"),
            ("n_misses", ddl.get("n_misses"), "deadlines.n_misses")):
        if fleet is None:
            continue
        got = sum(row[total] for row in rows)
        if got != fleet:
            errs.append(f"per-entry {total} sums to {got} != {label} "
                        f"{fleet} — the entry ledger leaks streams")
    by_entry: dict[tuple, int] = {}
    for i, s in enumerate(streams):
        if "entry" not in s or "entry_uid" not in s:
            break  # already reported by the stream-key check
        k = (s["entry"], s["entry_uid"])
        if k not in row_keys:
            errs.append(f"stream[{i}] bound to entry {k} absent from "
                        f"registry.entries")
            break
        by_entry[k] = by_entry.get(k, 0) + 1
    else:
        for row in rows:
            k = (row["name"], row["uid"])
            if by_entry.get(k, 0) != row["n_finished"]:
                errs.append(
                    f"entry {k}: n_finished {row['n_finished']} != "
                    f"{by_entry.get(k, 0)} streams bound to it")
    return errs


def _check_adaptation(art: dict) -> list[str]:
    """v5: the adaptation block must be internally consistent — a
    disabled engine reports zero updates, an enabled one names its rule
    and its per-lane counts sum to the fleet total."""
    errs = []
    ad = art.get("adaptation", {})
    if ADAPT_KEYS - set(ad):
        errs.append(f"adaptation missing {sorted(ADAPT_KEYS - set(ad))}")
        return errs
    if not isinstance(ad["enabled"], bool):
        errs.append(f"adaptation.enabled must be a bool, got "
                    f"{ad['enabled']!r}")
        return errs
    lanes = ad["lanes"]
    if not ad["enabled"]:
        if ad["n_updates"] != 0 or lanes:
            errs.append(f"disabled adaptation block carries updates: "
                        f"n_updates={ad['n_updates']}, "
                        f"{len(lanes)} lane rows")
        return errs
    if ad["rule"] not in ADAPT_RULES:
        errs.append(f"adaptation.rule must be one of {ADAPT_RULES}, got "
                    f"{ad['rule']!r}")
    if ad["lr_w"] < 0 or ad["lr_theta"] < 0:
        errs.append(f"adaptation learning rates must be >= 0: "
                    f"lr_w={ad['lr_w']}, lr_theta={ad['lr_theta']}")
    seen = set()
    for i, row in enumerate(lanes):
        miss = ADAPT_LANE_KEYS - set(row)
        if miss:
            errs.append(f"adaptation.lanes[{i}] missing {sorted(miss)}")
            return errs
        if row["lane"] in seen:
            errs.append(f"adaptation.lanes has duplicate lane "
                        f"{row['lane']}")
        seen.add(row["lane"])
        if row["n_updates"] <= 0:
            errs.append(f"adaptation.lanes[{i}] (lane {row['lane']}) has "
                        f"n_updates {row['n_updates']} — only lanes that "
                        f"updated belong in the block")
        if row["dw_norm"] < 0:
            errs.append(f"lane {row['lane']}: dw_norm must be >= 0, got "
                        f"{row['dw_norm']}")
    got = sum(row["n_updates"] for row in lanes)
    if got != ad["n_updates"]:
        errs.append(f"per-lane update counts sum to {got} != "
                    f"adaptation.n_updates {ad['n_updates']}")
    for key in ("accuracy_pre", "accuracy_post"):
        acc = ad[key]
        if acc is not None and not 0.0 <= acc <= 1.0:
            errs.append(f"adaptation.{key} out of range: {acc}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--streams", type=int, default=None,
                    help="expected served stream count")
    ap.add_argument("--paced", action="store_true",
                    help="require a paced run (deadline accounting "
                         "populated)")
    ap.add_argument("--max-miss-rate", type=float, default=None,
                    help="fail when the deadline-miss rate exceeds this "
                         "percentage (e.g. 1.0 = 1%%)")
    args = ap.parse_args()
    art = json.loads(open(args.path).read())
    errs = check(art, args.streams, paced=args.paced,
                 max_miss_rate=args.max_miss_rate)
    for e in errs:
        print(f"check_stream_stats: {e}", file=sys.stderr)
    if not errs:
        v = schema_version(art)
        lat, ddl = art["latency_ms"], art["deadlines"]
        devices = art["sharding"]["devices"] if v >= 3 else 1
        per_dev = (f" ({art['throughput']['events_per_s_per_device']:.0f}"
                   f"/device)" if v >= 3 else "")
        paced_note = (f", {ddl['n_misses']}/{ddl['n_deadlines']} deadline "
                      f"misses" if art["paced"] else "")
        entries_note = (
            f", {len(art['registry']['entries'])} registry entr"
            f"{'y' if len(art['registry']['entries']) == 1 else 'ies'}"
            if v >= 4 else "")
        adapt_note = ""
        if v >= 5 and art["adaptation"]["enabled"]:
            ad = art["adaptation"]
            adapt_note = (f", adapting ({ad['rule']}): "
                          f"{ad['n_updates']} updates on "
                          f"{len(ad['lanes'])} lane(s)")
        print(f"check_stream_stats: OK (v{v}) — {art['n_streams']} streams "
              f"on {devices} device(s), "
              f"readout p50={lat['readout_p50']:.2f}ms "
              f"p99={lat['readout_p99']:.2f}ms, "
              f"{art['throughput']['events_per_s']:.0f} events/s"
              f"{per_dev}{paced_note}{entries_note}{adapt_note}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
