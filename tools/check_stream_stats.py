#!/usr/bin/env python3
"""Assert a serving-stats artifact matches the p2m-stream-serving/v3
schema (docs/streaming.md). Stdlib only — the CI streaming-smoke step
runs it against the artifacts `launch/stream.py --smoke` just emitted
(one unpaced, one ``--paced``, one lane-sharded).

v3 adds the mesh ``sharding`` block (devices, bin_workers,
padded_capacity, lanes_per_shard, per_shard_admitted) and
``throughput.events_per_s_per_device``; the sharding ledger must be
internally consistent (lanes_per_shard x devices == padded_capacity >=
capacity, per-shard admits sum to n_admitted).

    python tools/check_stream_stats.py artifacts/stream/stream_serving_dvs128.json [--streams N]
    python tools/check_stream_stats.py --paced --max-miss-rate 1.0 paced.json
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "p2m-stream-serving/v3"
TOP_KEYS = {"schema", "deployed", "n_streams", "capacity",
            "chunks_per_window", "t_intg_ms", "accuracy", "paced",
            "admission", "deadlines", "streams", "latency_ms",
            "throughput", "sharding"}
STREAM_KEYS = {"stream_id", "label", "prediction", "correct", "n_events",
               "n_readouts", "n_coarse_frames", "offered_window",
               "admitted_window", "finished_window", "n_misses", "logits"}
ADMISSION_KEYS = {"offered_rate", "max_pending", "n_offered", "n_admitted",
                  "n_shed", "n_deferred", "max_open_streams"}
DEADLINE_KEYS = {"n_deadlines", "n_misses", "miss_rate", "margin_ms",
                 "histogram"}
MARGIN_KEYS = {"p50", "p90", "p99", "max"}
LATENCY_KEYS = {"readout_p50", "readout_p99", "readout_mean", "fold_p50",
                "fold_p99"}
THROUGHPUT_KEYS = {"wall_s", "events_per_s", "events_per_s_per_device",
                   "readouts_per_s", "streams_per_s"}
SHARDING_KEYS = {"devices", "bin_workers", "padded_capacity",
                 "lanes_per_shard", "per_shard_admitted"}


def check(art: dict, n_streams: int | None = None, paced: bool = False,
          max_miss_rate: float | None = None) -> list[str]:
    errs = []
    if art.get("schema") != SCHEMA:
        errs.append(f"schema {art.get('schema')!r} != {SCHEMA!r}")
    missing = TOP_KEYS - set(art)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
    streams = art.get("streams", [])
    if n_streams is not None and len(streams) != n_streams:
        errs.append(f"expected {n_streams} streams, got {len(streams)}")
    if art.get("n_streams") != len(streams):
        errs.append("n_streams does not match len(streams)")
    for i, s in enumerate(streams):
        miss = STREAM_KEYS - set(s)
        if miss:
            errs.append(f"stream[{i}] missing {sorted(miss)}")
            break
        if s["n_events"] <= 0 or s["n_readouts"] <= 0:
            errs.append(f"stream[{i}] has empty serving counters: {s}")
        if s["n_coarse_frames"] <= 0:
            errs.append(f"stream[{i}] produced no coarse backbone frames "
                        f"— its prediction is vacuous")
        if not 0 <= s["n_misses"] <= s["n_readouts"]:
            errs.append(f"stream[{i}] miss counter out of range: "
                        f"{s['n_misses']} of {s['n_readouts']} readouts")
    adm = art.get("admission", {})
    if ADMISSION_KEYS - set(adm):
        errs.append(f"admission missing "
                    f"{sorted(ADMISSION_KEYS - set(adm))}")
    else:
        if adm["n_offered"] != adm["n_admitted"] + adm["n_shed"]:
            errs.append(f"admission ledger does not balance: offered "
                        f"{adm['n_offered']} != admitted "
                        f"{adm['n_admitted']} + shed {adm['n_shed']}")
        if adm["n_admitted"] != len(streams):
            errs.append(f"n_admitted {adm['n_admitted']} != "
                        f"{len(streams)} served streams (every admitted "
                        f"stream must finish)")
        cap = art.get("capacity", 0)
        if adm["max_open_streams"] > cap:
            errs.append(f"max_open_streams {adm['max_open_streams']} "
                        f"exceeds capacity {cap} — streams were opened "
                        f"before a lane was free (eager admission)")
    ddl = art.get("deadlines", {})
    if DEADLINE_KEYS - set(ddl):
        errs.append(f"deadlines missing {sorted(DEADLINE_KEYS - set(ddl))}")
    else:
        if MARGIN_KEYS - set(ddl.get("margin_ms", {})):
            errs.append(f"deadlines.margin_ms missing "
                        f"{sorted(MARGIN_KEYS - set(ddl['margin_ms']))}")
        if not 0.0 <= ddl["miss_rate"] <= 1.0:
            errs.append(f"miss_rate out of range: {ddl['miss_rate']}")
        if ddl["n_misses"] > ddl["n_deadlines"]:
            errs.append(f"n_misses {ddl['n_misses']} > n_deadlines "
                        f"{ddl['n_deadlines']}")
        if art.get("paced"):
            if ddl["n_deadlines"] <= 0:
                errs.append("paced run recorded no deadlines")
        elif ddl["n_deadlines"] != 0:
            errs.append(f"unpaced run carries {ddl['n_deadlines']} "
                        f"deadlines — only paced readouts have them")
        if (max_miss_rate is not None
                and ddl["miss_rate"] * 100.0 > max_miss_rate):
            errs.append(f"miss rate {ddl['miss_rate']:.2%} exceeds "
                        f"--max-miss-rate {max_miss_rate}%")
    sh = art.get("sharding", {})
    if SHARDING_KEYS - set(sh):
        errs.append(f"sharding missing {sorted(SHARDING_KEYS - set(sh))}")
    else:
        if sh["devices"] < 1 or sh["bin_workers"] < 1:
            errs.append(f"sharding counts must be >= 1: {sh}")
        if sh["lanes_per_shard"] * sh["devices"] != sh["padded_capacity"]:
            errs.append(f"sharding geometry inconsistent: "
                        f"{sh['lanes_per_shard']} lanes/shard x "
                        f"{sh['devices']} devices != padded capacity "
                        f"{sh['padded_capacity']}")
        if sh["padded_capacity"] < art.get("capacity", 0):
            errs.append(f"padded_capacity {sh['padded_capacity']} < "
                        f"capacity {art.get('capacity')}")
        if len(sh["per_shard_admitted"]) != sh["devices"]:
            errs.append(f"per_shard_admitted has "
                        f"{len(sh['per_shard_admitted'])} entries for "
                        f"{sh['devices']} devices")
        elif (not (ADMISSION_KEYS - set(adm))
                and sum(sh["per_shard_admitted"]) != adm["n_admitted"]):
            errs.append(f"per-shard admits {sh['per_shard_admitted']} sum "
                        f"to {sum(sh['per_shard_admitted'])} != "
                        f"n_admitted {adm['n_admitted']}")
    if paced and not art.get("paced"):
        errs.append("--paced: artifact is not a paced run")
    if LATENCY_KEYS - set(art.get("latency_ms", {})):
        errs.append(f"latency_ms missing "
                    f"{sorted(LATENCY_KEYS - set(art.get('latency_ms', {})))}")
    thr = art.get("throughput", {})
    if THROUGHPUT_KEYS - set(thr):
        errs.append(f"throughput missing {sorted(THROUGHPUT_KEYS - set(thr))}")
    elif not thr["events_per_s"] > 0 or not thr["readouts_per_s"] > 0:
        errs.append(f"throughput not positive: {thr}")
    if not 0.0 <= art.get("accuracy", -1) <= 1.0:
        errs.append(f"accuracy out of range: {art.get('accuracy')}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--streams", type=int, default=None,
                    help="expected served stream count")
    ap.add_argument("--paced", action="store_true",
                    help="require a paced run (deadline accounting "
                         "populated)")
    ap.add_argument("--max-miss-rate", type=float, default=None,
                    help="fail when the deadline-miss rate exceeds this "
                         "percentage (e.g. 1.0 = 1%%)")
    args = ap.parse_args()
    art = json.loads(open(args.path).read())
    errs = check(art, args.streams, paced=args.paced,
                 max_miss_rate=args.max_miss_rate)
    for e in errs:
        print(f"check_stream_stats: {e}", file=sys.stderr)
    if not errs:
        lat, ddl = art["latency_ms"], art["deadlines"]
        paced_note = (f", {ddl['n_misses']}/{ddl['n_deadlines']} deadline "
                      f"misses" if art["paced"] else "")
        print(f"check_stream_stats: OK — {art['n_streams']} streams on "
              f"{art['sharding']['devices']} device(s), "
              f"readout p50={lat['readout_p50']:.2f}ms "
              f"p99={lat['readout_p99']:.2f}ms, "
              f"{art['throughput']['events_per_s']:.0f} events/s "
              f"({art['throughput']['events_per_s_per_device']:.0f}/device)"
              f"{paced_note}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
