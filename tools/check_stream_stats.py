#!/usr/bin/env python3
"""Assert a serving-stats artifact matches the p2m-stream-serving/v1
schema (docs/streaming.md). Stdlib only — the CI streaming-smoke step
runs it against the artifact `launch/stream.py --smoke` just emitted.

    python tools/check_stream_stats.py artifacts/stream/stream_serving_dvs128.json [--streams N]
"""
from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "p2m-stream-serving/v1"
TOP_KEYS = {"schema", "deployed", "n_streams", "capacity",
            "chunks_per_window", "t_intg_ms", "accuracy", "streams",
            "latency_ms", "throughput"}
STREAM_KEYS = {"stream_id", "label", "prediction", "correct", "n_events",
               "n_readouts", "n_coarse_frames", "logits"}
LATENCY_KEYS = {"readout_p50", "readout_p99", "readout_mean", "fold_p50",
                "fold_p99"}
THROUGHPUT_KEYS = {"wall_s", "events_per_s", "readouts_per_s",
                   "streams_per_s"}


def check(art: dict, n_streams: int | None = None) -> list[str]:
    errs = []
    if art.get("schema") != SCHEMA:
        errs.append(f"schema {art.get('schema')!r} != {SCHEMA!r}")
    missing = TOP_KEYS - set(art)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
    streams = art.get("streams", [])
    if n_streams is not None and len(streams) != n_streams:
        errs.append(f"expected {n_streams} streams, got {len(streams)}")
    if art.get("n_streams") != len(streams):
        errs.append("n_streams does not match len(streams)")
    for i, s in enumerate(streams):
        miss = STREAM_KEYS - set(s)
        if miss:
            errs.append(f"stream[{i}] missing {sorted(miss)}")
            break
        if s["n_events"] <= 0 or s["n_readouts"] <= 0:
            errs.append(f"stream[{i}] has empty serving counters: {s}")
        if s["n_coarse_frames"] <= 0:
            errs.append(f"stream[{i}] produced no coarse backbone frames "
                        f"— its prediction is vacuous")
    if LATENCY_KEYS - set(art.get("latency_ms", {})):
        errs.append(f"latency_ms missing "
                    f"{sorted(LATENCY_KEYS - set(art.get('latency_ms', {})))}")
    thr = art.get("throughput", {})
    if THROUGHPUT_KEYS - set(thr):
        errs.append(f"throughput missing {sorted(THROUGHPUT_KEYS - set(thr))}")
    elif not thr["events_per_s"] > 0 or not thr["readouts_per_s"] > 0:
        errs.append(f"throughput not positive: {thr}")
    if not 0.0 <= art.get("accuracy", -1) <= 1.0:
        errs.append(f"accuracy out of range: {art.get('accuracy')}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--streams", type=int, default=None,
                    help="expected stream count")
    args = ap.parse_args()
    art = json.loads(open(args.path).read())
    errs = check(art, args.streams)
    for e in errs:
        print(f"check_stream_stats: {e}", file=sys.stderr)
    if not errs:
        lat = art["latency_ms"]
        print(f"check_stream_stats: OK — {art['n_streams']} streams, "
              f"readout p50={lat['readout_p50']:.2f}ms "
              f"p99={lat['readout_p99']:.2f}ms, "
              f"{art['throughput']['events_per_s']:.0f} events/s")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
