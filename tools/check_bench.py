#!/usr/bin/env python3
"""Validate BENCH_*.json perf-trajectory records and flag regressions.

Two jobs (docs/benchmarks.md):

  * **schema gate** (always): every record must carry
    ``schema == "p2m-bench/v1"``, the required top-level keys, and
    well-formed entries (name + numeric-or-null timings + oracle
    ``max_err``). Exit 1 on any violation — CI gates on this.
  * **trajectory diff** (when the file is tracked): compare each entry's
    ``kernel_us`` AND ``xla_us`` against the committed record (``git show
    HEAD:BENCH_<name>.json``), plus the throughput meta fields
    (``meta.events_per_s``, ``meta.events_per_s_per_device``) where LOWER
    is the regression. Slowdowns beyond ``--max-regression`` (ratio,
    default 0 = report only) are flagged; with the flag set they fail the
    run. Timings on shared runners are noisy, so the default is advisory
    — ``max_err`` drift is what the kernels' own asserts gate.

    python tools/check_bench.py                 # all BENCH_*.json at root
    python tools/check_bench.py BENCH_kernels.json --max-regression 3.0
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SCHEMA = "p2m-bench/v1"
REQUIRED_KEYS = ("schema", "name", "commit", "backend", "interpret",
                 "generated", "entries")
ENTRY_KEYS = ("name", "xla_us", "kernel_us", "max_err", "meta")


def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate(record: dict, label: str) -> list[str]:
    """Schema violations for one parsed record (empty list = valid)."""
    errs = []
    if not isinstance(record, dict):
        return [f"{label}: record is not a JSON object"]
    for k in REQUIRED_KEYS:
        if k not in record:
            errs.append(f"{label}: missing key '{k}'")
    if record.get("schema") != SCHEMA:
        errs.append(f"{label}: schema {record.get('schema')!r} != {SCHEMA!r}")
    if errs:
        return errs
    if not isinstance(record["interpret"], bool):
        errs.append(f"{label}: 'interpret' must be a bool")
    for k in ("name", "commit", "backend", "generated"):
        if not isinstance(record[k], str) or not record[k]:
            errs.append(f"{label}: '{k}' must be a non-empty string")
    entries = record["entries"]
    if not isinstance(entries, list) or not entries:
        return errs + [f"{label}: 'entries' must be a non-empty list"]
    seen = set()
    for i, e in enumerate(entries):
        tag = f"{label}: entries[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{tag} is not an object")
            continue
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"{tag}: 'name' must be a non-empty string")
        elif name in seen:
            errs.append(f"{tag}: duplicate entry name {name!r}")
        else:
            seen.add(name)
        for k in ("xla_us", "kernel_us", "max_err"):
            if k not in e:
                errs.append(f"{tag}: missing key '{k}'")
            elif e[k] is not None and not _is_num(e[k]):
                errs.append(f"{tag}: '{k}' must be numeric or null")
            elif _is_num(e.get(k)) and e[k] < 0:
                errs.append(f"{tag}: '{k}' must be >= 0")
        if not isinstance(e.get("meta", {}), dict):
            errs.append(f"{tag}: 'meta' must be an object")
        unknown = set(e) - set(ENTRY_KEYS)
        if unknown:
            errs.append(f"{tag}: unknown keys {sorted(unknown)}")
    return errs


def committed_record(path: Path) -> dict | None:
    """The record as of HEAD, or None if untracked/new/outside the repo."""
    try:
        rel = path.resolve().relative_to(REPO).as_posix()
    except ValueError:
        return None
    try:
        out = subprocess.run(["git", "show", f"HEAD:{rel}"], cwd=REPO,
                             capture_output=True, text=True, timeout=20)
    except OSError:
        return None
    if out.returncode != 0:
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


# throughput meta fields where LOWER is the regression (timings above
# regress when they grow; rates regress when they shrink)
META_RATE_KEYS = ("events_per_s", "events_per_s_per_device")


def diff_trajectory(fresh: dict, prev: dict
                    ) -> list[tuple[str, float, float, float]]:
    """(entry, prev_val, new_val, slowdown_ratio) for entries slower than
    before — timing keys that grew, plus ``meta.*`` rate keys that
    shrank (ratio is old/new there, so >1 is always 'worse')."""
    prev_by = {e["name"]: e for e in prev.get("entries", [])
               if isinstance(e, dict)}
    regressions = []
    for e in fresh["entries"]:
        p = prev_by.get(e["name"])
        if not p:
            continue
        for k in ("kernel_us", "xla_us"):
            new, old = e.get(k), p.get(k)
            if _is_num(new) and _is_num(old) and old > 0 and new > old:
                regressions.append(
                    (f"{e['name']}.{k}", old, new, new / old))
        meta, p_meta = e.get("meta", {}), p.get("meta", {})
        if isinstance(meta, dict) and isinstance(p_meta, dict):
            for k in META_RATE_KEYS:
                new, old = meta.get(k), p_meta.get(k)
                if _is_num(new) and _is_num(old) and new > 0 and old > new:
                    regressions.append(
                        (f"{e['name']}.meta.{k}", old, new, old / new))
    return regressions


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("records", nargs="*", type=Path,
                    help="BENCH_*.json files (default: all at repo root)")
    ap.add_argument("--max-regression", type=float, default=0.0,
                    help="fail when kernel_us/xla_us grows by more than "
                         "this ratio vs the committed record (e.g. 3.0 = "
                         "3x slower); 0 = report only")
    args = ap.parse_args(argv)

    paths = args.records or sorted(REPO.glob("BENCH_*.json"))
    if not paths:
        print("check_bench: no BENCH_*.json records found", file=sys.stderr)
        return 1

    errors: list[str] = []
    gated: list[str] = []
    for path in paths:
        label = path.name
        try:
            record = json.loads(path.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{label}: unreadable ({e})")
            continue
        errs = validate(record, label)
        errors.extend(errs)
        if errs:
            continue
        prev = committed_record(path)
        if prev is None or validate(prev, label):
            print(f"check_bench: {label}: no committed baseline "
                  f"(new record) — schema OK")
            continue
        regs = diff_trajectory(record, prev)
        for name, old, new, ratio in regs:
            unit = "" if ".meta." in name else "us"
            line = (f"{label}: {name} {old:.1f}{unit} → {new:.1f}{unit} "
                    f"({ratio:.2f}x)")
            if args.max_regression and ratio > args.max_regression:
                gated.append(f"REGRESSION {line}")
            else:
                print(f"check_bench: slower: {line}")
        if not regs:
            print(f"check_bench: {label}: no slowdowns vs "
                  f"{prev['commit'][:10]}")

    for e in errors + gated:
        print(e, file=sys.stderr)
    n_bad = len(errors) + len(gated)
    print(f"check_bench: {len(paths)} records, "
          f"{'OK' if not n_bad else f'{n_bad} problems'}")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
