#!/usr/bin/env python3
"""Link-check the repo docs: README.md + docs/*.md + Python docstrings.

Verifies, offline and with no third-party deps:

  * relative file/directory links resolve from the linking file
    (``[x](docs/sweep.md)``, ``[y](../src/repro/core/sweep.py)``);
  * intra-doc and cross-doc anchors (``#section`` /
    ``path.md#section``) match a real heading, using GitHub's
    slugification (lowercase, strip punctuation, spaces → hyphens);
  * inline code spans are ignored; external http(s)/mailto links are
    skipped (no network in CI);
  * ``.md`` files name-dropped in Python docstrings (module / class /
    function level) under ``benchmarks/`` and ``tools/`` exist at the
    repo root — docstrings rot quietly when a doc is renamed
    (a ``kernel_bench.py`` docstring once pointed at a §Roofline
    section of a file that no longer carried it).

Exit code 1 with one line per broken reference. Run from the repo root
(CI: the docs job) or anywhere — paths resolve relative to this file.

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
INLINE_CODE_RE = re.compile(r"`[^`]*`")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def slugify(heading: str) -> str:
    """GitHub-style heading → anchor id."""
    h = INLINE_CODE_RE.sub(lambda m: m.group(0).strip("`"), heading)
    h = re.sub(r"[^\w\s-]", "", h.strip().lower())
    return re.sub(r"\s+", "-", h)


def anchors_of(md: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    return {slugify(h) for h in HEADING_RE.findall(text)}


def _rel(p: Path) -> str:
    try:
        return str(p.relative_to(REPO))
    except ValueError:       # files outside the repo (tests)
        return str(p)


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    text = CODE_FENCE_RE.sub("", text)
    text = INLINE_CODE_RE.sub("", text)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{_rel(md)}: broken path link "
                              f"'{target}' → {path_part}")
                continue
        else:
            dest = md
        if anchor:
            if dest.suffix != ".md":
                errors.append(f"{_rel(md)}: anchor on non-"
                              f"markdown target '{target}'")
            elif anchor not in anchors_of(dest):
                errors.append(f"{_rel(md)}: broken anchor "
                              f"'{target}' (no heading '#{anchor}' in "
                              f"{_rel(dest)})")
    return errors


MD_REF_RE = re.compile(r"(?<![\w/])([\w./-]+\.md)(?:\s+§([\w.-]+))?")


def py_files() -> list[Path]:
    files = []
    for d in ("benchmarks", "tools"):
        root = REPO / d
        if root.is_dir():
            files += sorted(root.glob("*.py"))
    return files


def _docstrings(tree: ast.Module) -> list[str]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            doc = ast.get_docstring(node, clean=False)
            if doc:
                out.append(doc)
    return out


RST_LITERAL_RE = re.compile(r"``[^`]*``")


def check_py_docstrings(py: Path) -> list[str]:
    """Broken ``.md`` references (path or § section) in ``py``'s docstrings.

    Paths resolve against the repo root, then against the file's own
    directory. A ``§Section`` suffix must match a heading of the target
    doc (substring, case-insensitive) — this is what catches a docstring
    pointing at a section that moved to another file.
    """
    try:
        tree = ast.parse(py.read_text(encoding="utf-8"))
    except SyntaxError as e:
        return [f"{_rel(py)}: unparseable ({e})"]
    errors = []
    for doc in _docstrings(tree):
        doc = RST_LITERAL_RE.sub("", doc)     # skip ``code`` literals
        for ref, section in MD_REF_RE.findall(doc):
            dest = REPO / ref
            if not dest.exists():
                dest = py.parent / ref
            if not dest.exists():
                errors.append(f"{_rel(py)}: docstring references "
                              f"missing doc '{ref}'")
                continue
            if section:
                text = CODE_FENCE_RE.sub("", dest.read_text(encoding="utf-8"))
                heads = [h.lower() for h in HEADING_RE.findall(text)]
                if not any(section.lower() in h for h in heads):
                    errors.append(
                        f"{_rel(py)}: docstring references '{ref} "
                        f"§{section}' but {_rel(dest)} has no such heading")
    return errors


def main() -> int:
    files = doc_files()
    if not files:
        print("check_docs: no README.md / docs/*.md found", file=sys.stderr)
        return 1
    pys = py_files()
    errors = [e for f in files for e in check_file(f)]
    errors += [e for f in pys for e in check_py_docstrings(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} docs + {len(pys)} py files, "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
