#!/usr/bin/env python3
"""Link-check the repo docs: README.md + docs/*.md.

Verifies, offline and with no third-party deps:

  * relative file/directory links resolve from the linking file
    (``[x](docs/sweep.md)``, ``[y](../src/repro/core/sweep.py)``);
  * intra-doc and cross-doc anchors (``#section`` /
    ``path.md#section``) match a real heading, using GitHub's
    slugification (lowercase, strip punctuation, spaces → hyphens);
  * inline code spans are ignored; external http(s)/mailto links are
    skipped (no network in CI).

Exit code 1 with one line per broken reference. Run from the repo root
(CI: the docs job) or anywhere — paths resolve relative to this file.

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
INLINE_CODE_RE = re.compile(r"`[^`]*`")


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def slugify(heading: str) -> str:
    """GitHub-style heading → anchor id."""
    h = INLINE_CODE_RE.sub(lambda m: m.group(0).strip("`"), heading)
    h = re.sub(r"[^\w\s-]", "", h.strip().lower())
    return re.sub(r"\s+", "-", h)


def anchors_of(md: Path) -> set[str]:
    text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    return {slugify(h) for h in HEADING_RE.findall(text)}


def _rel(p: Path) -> str:
    try:
        return str(p.relative_to(REPO))
    except ValueError:       # files outside the repo (tests)
        return str(p)


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    text = CODE_FENCE_RE.sub("", text)
    text = INLINE_CODE_RE.sub("", text)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{_rel(md)}: broken path link "
                              f"'{target}' → {path_part}")
                continue
        else:
            dest = md
        if anchor:
            if dest.suffix != ".md":
                errors.append(f"{_rel(md)}: anchor on non-"
                              f"markdown target '{target}'")
            elif anchor not in anchors_of(dest):
                errors.append(f"{_rel(md)}: broken anchor "
                              f"'{target}' (no heading '#{anchor}' in "
                              f"{_rel(dest)})")
    return errors


def main() -> int:
    files = doc_files()
    if not files:
        print("check_docs: no README.md / docs/*.md found", file=sys.stderr)
        return 1
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} files, "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
