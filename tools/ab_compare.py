#!/usr/bin/env python3
"""A/B comparison of serving accuracy from v4+ serving-stats artifacts.

Answers "is variant B actually better than variant A, or is the gap
noise?" for the two comparisons the serving stack produces:

  * **two artifacts** (pre/post-adaptation, or the same stream set
    served under two deployments): streams are PAIRED by ``stream_id``
    (labels must agree pair-by-pair — same seed ⇒ same replayed
    streams), and the verdict comes from the exact two-sided binomial
    **sign test** on the discordant pairs plus a seeded **paired
    bootstrap** CI on the accuracy gap;
  * **one artifact, two registry entries** (``--entries A B``): the
    per-entry accuracy rows cover DIFFERENT streams, so the test is the
    unpaired analogue — a seeded **permutation test** on the accuracy
    gap plus an unpaired bootstrap CI.

Either way the last line is the machine-greppable verdict::

    verdict: B vs A dacc=+0.250 ci95=[+0.063,+0.438] p=0.0213 n=32 — SIGNIFICANT (alpha=0.05)

Exit codes: 0 = comparison ran (significant or not), 2 = bad input
(unknown schema, no overlapping streams, label mismatch, unknown entry).

    python tools/ab_compare.py frozen.json adapted.json
    python tools/ab_compare.py mixed.json --entries nullified basic
"""
from __future__ import annotations

import argparse
import json
import math
import random
import sys

MIN_VERSION = 4
BOOT = 2000


def schema_version(art: dict) -> int:
    s = str(art.get("schema") or "")
    prefix = "p2m-stream-serving/v"
    if not s.startswith(prefix):
        raise ValueError(f"not a serving-stats artifact (schema={s!r})")
    v = int(s[len(prefix):])
    if v < MIN_VERSION:
        raise ValueError(
            f"schema v{v} predates per-entry stream rows — ab_compare "
            f"needs v{MIN_VERSION}+")
    return v


def stream_rows(art: dict, entry: str | None = None) -> dict[int, dict]:
    """stream_id -> row, for labeled streams (optionally one registry
    entry's streams only)."""
    rows = {}
    for row in art.get("streams") or []:
        if row.get("label") is None or row["label"] < 0:
            continue
        if entry is not None and row.get("entry") != entry:
            continue
        rows[int(row["stream_id"])] = row
    if entry is not None and not rows:
        names = sorted({r.get("entry") for r in art.get("streams") or []})
        raise ValueError(f"no labeled streams for entry {entry!r} "
                         f"(entries present: {names})")
    return rows


def sign_test(n01: int, n10: int) -> float:
    """Exact two-sided binomial sign test on the discordant pairs:
    ``n01`` = A correct / B wrong, ``n10`` = A wrong / B correct. Under
    H0 each discordant pair is a fair coin."""
    n = n01 + n10
    if n == 0:
        return 1.0
    k = min(n01, n10)
    tail = sum(math.comb(n, i) for i in range(k + 1)) / 2.0 ** n
    return min(1.0, 2.0 * tail)


def paired_compare(rows_a: dict[int, dict], rows_b: dict[int, dict],
                   *, boot: int = BOOT, seed: int = 0) -> dict:
    """Pair by stream_id; sign test + paired bootstrap CI on the gap."""
    ids = sorted(set(rows_a) & set(rows_b))
    if not ids:
        raise ValueError("no overlapping labeled stream_ids — the two "
                         "artifacts serve disjoint streams")
    bad = [i for i in ids if rows_a[i]["label"] != rows_b[i]["label"]]
    if bad:
        raise ValueError(
            f"stream_ids {bad[:5]} carry different labels in the two "
            f"artifacts — these are not the same replayed streams "
            f"(different seed or source?)")
    pairs = [(bool(rows_a[i]["correct"]), bool(rows_b[i]["correct"]))
             for i in ids]
    acc_a = sum(a for a, _ in pairs) / len(pairs)
    acc_b = sum(b for _, b in pairs) / len(pairs)
    n01 = sum(1 for a, b in pairs if a and not b)
    n10 = sum(1 for a, b in pairs if b and not a)
    p = sign_test(n01, n10)
    rng = random.Random(seed)
    deltas = []
    for _ in range(boot):
        sample = [pairs[rng.randrange(len(pairs))] for _ in pairs]
        deltas.append(sum(b for _, b in sample) / len(sample)
                      - sum(a for a, _ in sample) / len(sample))
    deltas.sort()
    lo = deltas[int(0.025 * (boot - 1))]
    hi = deltas[int(0.975 * (boot - 1))]
    return {"mode": "paired", "n": len(pairs), "acc_a": acc_a,
            "acc_b": acc_b, "delta": acc_b - acc_a, "ci": (lo, hi),
            "p": p, "n01": n01, "n10": n10}


def unpaired_compare(rows_a: dict[int, dict], rows_b: dict[int, dict],
                     *, boot: int = BOOT, seed: int = 0) -> dict:
    """Different stream sets (entry-vs-entry inside one artifact):
    permutation test on the accuracy gap + unpaired bootstrap CI."""
    xs = [bool(r["correct"]) for r in rows_a.values()]
    ys = [bool(r["correct"]) for r in rows_b.values()]
    if not xs or not ys:
        raise ValueError("one side has no labeled streams")
    acc_a, acc_b = sum(xs) / len(xs), sum(ys) / len(ys)
    delta = acc_b - acc_a
    rng = random.Random(seed)
    pooled = xs + ys
    hits = 0
    for _ in range(boot):
        rng.shuffle(pooled)
        d = (sum(pooled[len(xs):]) / len(ys)
             - sum(pooled[:len(xs)]) / len(xs))
        if abs(d) >= abs(delta) - 1e-12:
            hits += 1
    p = (hits + 1) / (boot + 1)
    deltas = []
    for _ in range(boot):
        sa = [xs[rng.randrange(len(xs))] for _ in xs]
        sb = [ys[rng.randrange(len(ys))] for _ in ys]
        deltas.append(sum(sb) / len(sb) - sum(sa) / len(sa))
    deltas.sort()
    lo = deltas[int(0.025 * (boot - 1))]
    hi = deltas[int(0.975 * (boot - 1))]
    return {"mode": "unpaired", "n": len(xs) + len(ys), "acc_a": acc_a,
            "acc_b": acc_b, "delta": delta, "ci": (lo, hi), "p": p}


def verdict_line(res: dict, name_a: str, name_b: str,
                 alpha: float) -> str:
    sig = "SIGNIFICANT" if res["p"] < alpha else "NOT SIGNIFICANT"
    lo, hi = res["ci"]
    return (f"verdict: {name_b} vs {name_a} dacc={res['delta']:+.3f} "
            f"ci95=[{lo:+.3f},{hi:+.3f}] p={res['p']:.4f} n={res['n']} "
            f"— {sig} (alpha={alpha:g})")


def main() -> int:
    ap = argparse.ArgumentParser(
        description="paired/unpaired A/B accuracy comparison over "
                    "serving-stats artifacts")
    ap.add_argument("artifact_a", help="serving artifact A (baseline)")
    ap.add_argument("artifact_b", nargs="?", default=None,
                    help="serving artifact B; omitted with --entries")
    ap.add_argument("--entries", nargs=2, metavar=("A", "B"), default=None,
                    help="compare two registry entries inside ONE "
                         "artifact (unpaired)")
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--bootstrap", type=int, default=BOOT,
                    help="bootstrap/permutation resamples")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if (args.artifact_b is None) == (args.entries is None):
        print("ab_compare: pass either TWO artifacts or ONE artifact "
              "with --entries A B", file=sys.stderr)
        return 2
    try:
        art_a = json.loads(open(args.artifact_a).read())
        schema_version(art_a)
        if args.entries is not None:
            ea, eb = args.entries
            res = unpaired_compare(stream_rows(art_a, ea),
                                   stream_rows(art_a, eb),
                                   boot=args.bootstrap, seed=args.seed)
            name_a, name_b = f"entry:{ea}", f"entry:{eb}"
        else:
            art_b = json.loads(open(args.artifact_b).read())
            schema_version(art_b)
            res = paired_compare(stream_rows(art_a), stream_rows(art_b),
                                 boot=args.bootstrap, seed=args.seed)
            name_a, name_b = args.artifact_a, args.artifact_b
    except (OSError, ValueError, KeyError) as e:
        print(f"ab_compare: {e}", file=sys.stderr)
        return 2
    print(f"ab_compare: {res['mode']} comparison, n={res['n']}: "
          f"acc_a={res['acc_a']:.3f} acc_b={res['acc_b']:.3f}")
    if res["mode"] == "paired":
        print(f"ab_compare: discordant pairs: A-only-correct="
              f"{res['n01']} B-only-correct={res['n10']} (sign test)")
    print(verdict_line(res, name_a, name_b, args.alpha))
    return 0


if __name__ == "__main__":
    sys.exit(main())
